"""Fault-tolerant cascade serving (repro.serving.resilience): seeded
deterministic fault injection, retry/backoff on fake clocks, circuit
breaker transitions, and the failover semantics through both cascade
paths — the offline executor and the parallel tier scheduler.

Tier-1 discipline: every time-dependent test runs on an injected fake
clock (no wall-clock sleeps) — backoffs are recorded against virtual
time, breaker cooldowns are walked by advancing a variable.
"""
import asyncio

import numpy as np
import pytest

from repro.core.cascade import CascadeTier, execute_cascade
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.serving.ingress import IngressQueue
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.resilience import (BreakerConfig, CircuitBreaker,
                                      FaultSpec, FaultyTier, RateLimitError,
                                      RetryPolicy, TierFault, TierHealth,
                                      TierTimeout, TransientError,
                                      VirtualClock, invoke_with_retry,
                                      wrap_tiers)
from repro.serving.sched import (SLOConfig, TierScheduler, rank_speculation,
                                 speculation_ev)


def _tier(name="t", base=0.0):
    return CascadeTier(name, lambda q, b=base: (
        np.asarray(q, np.float64) + b, np.full(len(q), b + 1.0)))


class _FakeClock:
    def __init__(self):
        self.now = 0.0
        self.slept: list[float] = []

    def __call__(self):
        return self.now

    def sleep(self, s):
        self.slept.append(s)
        self.now += s


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


def test_fault_spec_validation_and_parse():
    with pytest.raises(ValueError, match="error_rate"):
        FaultSpec(error_rate=1.5)
    with pytest.raises(ValueError, match="<= 1"):
        FaultSpec(error_rate=0.6, timeout_rate=0.6)
    with pytest.raises(ValueError, match="start < end"):
        FaultSpec(outage=(2.0, 1.0))
    with pytest.raises(ValueError, match="max_faults"):
        FaultSpec(max_faults=-1)
    assert not FaultSpec().enabled
    assert FaultSpec(outage=(0.0, 1.0)).enabled
    sp = FaultSpec.parse("error=0.05,timeout=0.1,spike=0.2@0.03,"
                         "rlim=1:2,outage=3:4,max=7,seed=9")
    assert sp.error_rate == 0.05 and sp.timeout_rate == 0.1
    assert sp.spike_rate == 0.2 and sp.spike_s == 0.03
    assert sp.rate_limit == (1.0, 2.0) and sp.outage == (3.0, 4.0)
    assert sp.max_faults == 7 and sp.seed == 9
    with pytest.raises(ValueError, match="unknown"):
        FaultSpec.parse("explode=1.0")
    with pytest.raises(ValueError, match="key=value"):
        FaultSpec.parse("error")


def test_faulty_tier_deterministic_schedule():
    """The fault sequence is a pure function of (seed, invoke index):
    two wrappers of the same spec fire on exactly the same calls."""
    spec = FaultSpec(error_rate=0.3, timeout_rate=0.2, seed=42)
    chunk = np.arange(4.0)

    def trace(ft):
        out = []
        for _ in range(40):
            try:
                ft.invoke(chunk)
                out.append("ok")
            except TierTimeout:
                out.append("timeout")
            except TransientError:
                out.append("error")
        return out

    t1, t2 = FaultyTier(_tier(), spec), FaultyTier(_tier(), spec)
    run1, run2 = trace(t1), trace(t2)
    assert run1 == run2
    assert run1.count("error") > 0 and run1.count("timeout") > 0
    assert t1.injected == t2.injected
    assert t1.calls == 40
    # a different seed produces a different schedule
    assert trace(FaultyTier(_tier(), FaultSpec(
        error_rate=0.3, timeout_rate=0.2, seed=43))) != run1


def test_faulty_tier_windows_and_spike_on_fake_clock():
    clk = _FakeClock()
    spec = FaultSpec(rate_limit=(1.0, 2.0), outage=(3.0, 4.0))
    ft = FaultyTier(_tier(), spec, clock=clk, sleep=clk.sleep)
    chunk = np.arange(3.0)
    ft.invoke(chunk)                              # t=0: clean
    clk.now = 1.5
    with pytest.raises(RateLimitError):
        ft.invoke(chunk)
    clk.now = 3.5
    with pytest.raises(TransientError):
        ft.invoke(chunk)
    clk.now = 4.5                                 # windows passed: clean
    ft.invoke(chunk)
    assert ft.injected["rate_limit"] == 1 and ft.injected["outage"] == 1
    # spikes sleep on the injected sleep and still succeed
    sp = FaultyTier(_tier(), FaultSpec(spike_rate=1.0, spike_s=0.07),
                    clock=clk, sleep=clk.sleep)
    a, c = sp.invoke(chunk)
    assert clk.slept == [0.07] and len(a) == 3
    assert sp.injected["spike"] == 1


def test_faulty_tier_max_faults_budget():
    ft = FaultyTier(_tier(), FaultSpec(error_rate=1.0, max_faults=2))
    chunk = np.arange(2.0)
    for _ in range(2):
        with pytest.raises(TransientError):
            ft.invoke(chunk)
    ft.invoke(chunk)                              # budget spent: clean
    assert ft.injected["error"] == 2


def test_wrap_tiers_disabled_is_absent():
    tiers = [_tier("a"), _tier("b")]
    assert wrap_tiers(tiers, None) == tiers       # same objects
    out = wrap_tiers(tiers, [None, FaultSpec(error_rate=0.5)])
    assert out[0] is tiers[0] and isinstance(out[1], FaultyTier)
    # inactive spec: also untouched
    out = wrap_tiers(tiers, [FaultSpec(), FaultSpec()])
    assert out[0] is tiers[0] and out[1] is tiers[1]
    # broadcast offsets the per-tier seeds so tiers don't fault in step
    out = wrap_tiers(tiers, FaultSpec(error_rate=0.5, seed=3))
    assert out[0].spec.seed != out[1].spec.seed
    with pytest.raises(ValueError, match="fault specs"):
        wrap_tiers(tiers, [FaultSpec(error_rate=0.5)])


def test_builder_maps_marketplace_faults_onto_learned_cascade():
    # per-tier fault lists handed to BuildConfig are indexed by the
    # marketplace order; the learned cascade keeps a subsequence, so the
    # builder selects the matching entries (a 3-tier marketplace pruned
    # to tiers [0, 2] keeps specs 0 and 2, dropping spec 1)
    from repro.serving.builder import _select_tier_faults

    specs = [None, FaultSpec(error_rate=0.5), FaultSpec(timeout_rate=0.2)]
    assert _select_tier_faults(specs, 3, [0, 2]) == [None, specs[2]]
    assert _select_tier_faults(specs, 3, [1]) == [specs[1]]
    # broadcast / disabled pass straight through, length-independent
    bcast = FaultSpec(error_rate=0.1)
    assert _select_tier_faults(bcast, 3, [0]) is bcast
    assert _select_tier_faults(None, 3, [0, 1]) is None
    with pytest.raises(ValueError, match="marketplace"):
        _select_tier_faults(specs[:2], 3, [0, 2])


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_mult"):
        RetryPolicy(backoff_mult=0.5)
    with pytest.raises(ValueError, match="jitter_frac"):
        RetryPolicy(jitter_frac=1.0)
    with pytest.raises(ValueError, match="accounting"):
        RetryPolicy(accounting="free")


def test_backoff_deterministic_jitter():
    pol = RetryPolicy(backoff_s=0.1, backoff_mult=2.0, max_backoff_s=0.3,
                      jitter_frac=0.25, seed=5)
    for attempt, base in ((0, 0.1), (1, 0.2), (2, 0.3), (5, 0.3)):
        b = pol.backoff(attempt, token=3)
        assert base * 0.75 <= b <= base * 1.25
        assert b == pol.backoff(attempt, token=3)      # deterministic
    assert pol.backoff(0, token=3) != pol.backoff(0, token=4)
    # zero jitter: exact exponential with cap
    flat = RetryPolicy(backoff_s=0.1, jitter_frac=0.0, max_backoff_s=0.25)
    assert [flat.backoff(k) for k in range(3)] == [0.1, 0.2, 0.25]


def test_may_retry_bounded_and_deadline_aware():
    pol = RetryPolicy(max_attempts=3, backoff_s=0.1, jitter_frac=0.0)
    assert pol.may_retry(0, now=0.0, deadline=None)
    assert pol.may_retry(1, now=0.0, deadline=None)
    assert not pol.may_retry(2, now=0.0, deadline=None)   # exhausted
    # backoff + predicted service must land before the deadline
    assert pol.may_retry(0, now=0.0, deadline=0.5, predicted_s=0.3)
    assert not pol.may_retry(0, now=0.0, deadline=0.5, predicted_s=0.5)
    assert not pol.may_retry(0, now=0.45, deadline=0.5)


def _flaky(fail_n: int, kind=TransientError):
    """A tier whose first ``fail_n`` invokes raise ``kind``."""
    calls = {"n": 0}

    def invoke(q):
        calls["n"] += 1
        if calls["n"] <= fail_n:
            raise kind(f"injected #{calls['n']}")
        return np.asarray(q, np.float64), np.full(len(q), 2.0)

    t = CascadeTier("flaky", invoke)
    return t, calls


def test_invoke_with_retry_success_and_accounting():
    clk = _FakeClock()
    pol = RetryPolicy(max_attempts=4, backoff_s=0.1, jitter_frac=0.0)
    tier, calls = _flaky(2)
    seen = []
    a, c, attempts, waited = invoke_with_retry(
        tier, np.arange(3.0), pol, clock=clk, sleep=clk.sleep,
        on_attempt_fail=lambda k, e: seen.append(k))
    assert attempts == 3 and calls["n"] == 3
    assert seen == [0, 1]
    assert waited == pytest.approx(0.1 + 0.2)
    assert clk.now == pytest.approx(0.3)          # virtual time only
    assert (c == 2.0).all()                       # "success": one bill
    # "all_attempts": the successful cost is scaled by the attempt count
    tier, _ = _flaky(2)
    _, c, _, _ = invoke_with_retry(
        tier, np.arange(3.0), RetryPolicy(
            max_attempts=4, backoff_s=0.1, jitter_frac=0.0,
            accounting="all_attempts"),
        clock=clk, sleep=clk.sleep)
    assert (c == 6.0).all()


def test_invoke_with_retry_exhausted_and_deadline():
    clk = _FakeClock()
    pol = RetryPolicy(max_attempts=2, backoff_s=0.1, jitter_frac=0.0)
    tier, calls = _flaky(99)
    with pytest.raises(TransientError):
        invoke_with_retry(tier, np.arange(2.0), pol,
                          clock=clk, sleep=clk.sleep)
    assert calls["n"] == 2                        # bounded
    # a deadline that forbids the retry fails fast on attempt 1
    tier, calls = _flaky(99)
    with pytest.raises(TransientError):
        invoke_with_retry(tier, np.arange(2.0),
                          RetryPolicy(max_attempts=5, backoff_s=0.1,
                                      jitter_frac=0.0),
                          clock=clk, sleep=clk.sleep,
                          deadline=clk.now + 0.05)
    assert calls["n"] == 1
    # non-TierFault exceptions are programming errors: never retried
    boom = CascadeTier("boom", lambda q: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        invoke_with_retry(boom, np.arange(2.0), pol,
                          clock=clk, sleep=clk.sleep)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_config_validation():
    with pytest.raises(ValueError, match="window"):
        BreakerConfig(window=0)
    with pytest.raises(ValueError, match="fail_rate"):
        BreakerConfig(fail_rate=0.0)
    with pytest.raises(ValueError, match="min_samples"):
        BreakerConfig(window=4, min_samples=5)
    with pytest.raises(ValueError, match="cooldown_s"):
        BreakerConfig(cooldown_s=-1.0)


def test_breaker_state_machine_on_explicit_now():
    b = CircuitBreaker(BreakerConfig(window=4, fail_rate=0.5,
                                     min_samples=2, cooldown_s=1.0))
    assert b.state(0.0) == "closed" and b.available(0.0)
    assert not b.record(False, 0.0)               # 1 sample < min_samples
    assert b.record(False, 0.1)                   # 2/2 failed: TRIP
    assert b.state(0.2) == "open" and not b.available(0.2)
    assert b.trips == 1
    # cooldown elapses -> half-open admits the probe
    assert b.state(1.2) == "half_open" and b.available(1.2)
    # failed probe re-trips for another cooldown
    assert b.record(False, 1.3)
    assert b.state(1.4) == "open" and b.trips == 2
    # successful probe recovers
    assert b.state(2.4) == "half_open"
    assert not b.record(True, 2.5)
    assert b.state(2.6) == "closed" and b.recoveries == 1
    snap = b.snapshot(2.6)
    assert snap["state"] == "closed" and snap["trips"] == 2
    # a mixed window below the rate stays closed
    for ok in (True, True, True, False):
        b.record(ok, 3.0)
    assert b.state(3.0) == "closed"


def test_breaker_ramp_validation():
    with pytest.raises(ValueError, match="probe_bucket"):
        BreakerConfig(probe_bucket=0)
    with pytest.raises(ValueError, match="probe_refill_per_s"):
        BreakerConfig(probe_refill_per_s=-1.0)
    with pytest.raises(ValueError, match="recovery_successes"):
        BreakerConfig(recovery_successes=0)
    with pytest.raises(ValueError, match="never close"):
        BreakerConfig(recovery_successes=3)      # bucket 1, no refill
    BreakerConfig(recovery_successes=3, probe_bucket=3)
    BreakerConfig(recovery_successes=3, probe_refill_per_s=1.0)


def _tripped(cfg, now=0.0):
    b = CircuitBreaker(cfg)
    b.record(False, now)
    assert b.state(now) == "open"
    return b


def test_breaker_ramped_recovery_closes_after_n_probes():
    """ISSUE 10: half-open is a token bucket — ``recovery_successes``
    successful probes close the breaker, not the first one."""
    cfg = BreakerConfig(window=4, fail_rate=0.5, min_samples=1,
                        cooldown_s=1.0, probe_bucket=3,
                        recovery_successes=3)
    b = _tripped(cfg)
    assert b.state(1.0) == "half_open"
    assert not b.record(True, 1.1)
    assert b.state(1.1) == "half_open"           # 1/3: still ramping
    assert not b.record(True, 1.2)
    assert b.state(1.2) == "half_open"           # 2/3
    assert not b.record(True, 1.3)
    assert b.state(1.3) == "closed"              # ramp complete
    assert b.recoveries == 1


def test_breaker_ramp_failure_retrips():
    cfg = BreakerConfig(window=4, fail_rate=0.5, min_samples=1,
                        cooldown_s=1.0, probe_bucket=3,
                        recovery_successes=3)
    b = _tripped(cfg)
    assert b.state(1.0) == "half_open"
    assert not b.record(True, 1.1)               # 1/3 into the ramp
    assert b.record(False, 1.2)                  # mid-ramp failure: TRIP
    assert b.state(1.3) == "open" and b.trips == 2
    # the next half-open entry starts a fresh ramp (oks reset)
    assert b.state(2.3) == "half_open"
    assert b.snapshot(2.3)["probe_oks"] == 0


def test_breaker_token_bucket_meters_probes():
    """Tokens bound the probe rate: the burst drains after
    ``probe_bucket`` recorded probes, then ``available`` stays False
    until the refill rate mints the next token — a fleet cannot
    thundering-herd a barely-recovered tier."""
    cfg = BreakerConfig(window=8, fail_rate=0.5, min_samples=1,
                        cooldown_s=1.0, probe_bucket=2,
                        probe_refill_per_s=1.0, recovery_successes=4)
    b = _tripped(cfg)
    assert b.available(1.0)                      # burst token 1
    assert not b.record(True, 1.0)
    assert b.available(1.0)                      # burst token 2
    assert not b.record(True, 1.0)
    assert not b.available(1.0)                  # bucket drained
    assert not b.available(1.5)                  # 0.5 tokens: still short
    assert b.available(2.0)                      # refill minted one
    assert not b.record(True, 2.0)               # 3/4
    assert not b.available(2.0)
    assert b.available(3.0)
    assert not b.record(True, 3.0)               # 4/4: closed
    assert b.state(3.0) == "closed" and b.recoveries == 1


def test_breaker_default_ramp_is_classic_single_probe():
    """Defaults (bucket 1, one success, no refill) replay the exact
    pre-ramp half-open transcript — opt-in means bit-identical off."""
    cfg = BreakerConfig(window=4, fail_rate=0.5, min_samples=2,
                        cooldown_s=1.0)
    b = CircuitBreaker(cfg)
    transcript = []
    for ok, now in ((False, 0.0), (False, 0.1), (True, 1.2),
                    (False, 1.3), (False, 2.4), (True, 3.5)):
        avail = b.available(now)
        tripped = b.record(ok, now)
        transcript.append((b.state(now), avail, tripped))
    assert transcript == [
        ("closed", True, False),
        ("open", True, True),          # 2/2 failures: trip
        ("closed", True, False),       # cooldown over, probe ok: recover
        ("closed", True, False),       # 1 sample < min_samples
        ("open", True, True),          # 2/2 failures again
        ("closed", True, False),       # second recovery
    ]
    assert b.trips == 2 and b.recoveries == 2


def test_tier_health_registry_sums_counters():
    h = TierHealth(3, BreakerConfig(window=2, fail_rate=0.5,
                                    min_samples=1, cooldown_s=10.0))
    assert h.record(1, False, 0.0)                # tier 1 trips
    assert not h.available(1, 0.1)
    assert h.available(0, 0.1) and h.available(2, 0.1)
    h.record(1, True, 20.0)                       # half-open probe: recover
    assert h.trips == 1 and h.recoveries == 1
    assert len(h.snapshot(20.0)) == 3


def test_slo_config_validates_resilience_dials():
    with pytest.raises(ValueError, match="retry"):
        SLOConfig(retry=3)
    with pytest.raises(ValueError, match="breaker"):
        SLOConfig(breaker="on")
    slo = SLOConfig(retry=RetryPolicy(), breaker=BreakerConfig())
    assert slo.retry.max_attempts == 3


# ---------------------------------------------------------------------------
# offline executor failover (core.cascade.execute_cascade)
# ---------------------------------------------------------------------------


def _mk_tiers():
    return [_tier("a", 0.0), _tier("b", 10.0), _tier("c", 100.0)]


def _scorer(q, a, j):
    return np.full(len(q), 0.9 if j else 0.3)


def test_offline_faults_without_dials_crash():
    """No retry, no breaker: an injected fault is fatal — the
    no-resilience baseline keeps failing loudly."""
    ft = wrap_tiers(_mk_tiers(), FaultSpec(error_rate=1.0, seed=1))
    with pytest.raises(TransientError):
        execute_cascade(ft, [0.5, 0.5], _scorer, np.arange(8.0),
                        batch_size=4)


def test_offline_failover_past_sick_tier():
    clk = _FakeClock()
    specs = [None, FaultSpec(error_rate=1.0, seed=2), None]
    res = execute_cascade(
        wrap_tiers(_mk_tiers(), specs), [0.5, 0.5], _scorer,
        np.arange(8.0), batch_size=2,
        retry=RetryPolicy(max_attempts=2, backoff_s=0.01, jitter_frac=0.0),
        breaker=BreakerConfig(window=4, fail_rate=0.5, min_samples=2,
                              cooldown_s=100.0),
        clock=clk, sleep=clk.sleep)
    # every row failed over tier b and answered at tier c
    assert (res["stopped_at"] == 2).all()
    assert np.array_equal(np.asarray(res["answers"], np.float64),
                          np.arange(8.0) + 100.0)
    r = res["resilience"]
    assert r["failovers"] == 8 and r["retries"] == 4
    assert r["trips"] == 1 and r["shed"] == 0
    assert r["breakers"][1]["state"] == "open"
    # failed invokes charge nothing: cost = tier a + tier c only
    assert (res["cost"] == 1.0 + 101.0).all()


def test_offline_last_tier_failure_falls_back_or_sheds():
    # last tier down: rows fall back to their best-scoring earlier
    # rejected answer (tier b, score 0.9 > tier a's 0.3)
    specs = [None, None, FaultSpec(error_rate=1.0, seed=3)]
    res = execute_cascade(
        wrap_tiers(_mk_tiers(), specs), [0.95, 0.95], _scorer,
        np.arange(6.0), batch_size=3, retry=RetryPolicy(max_attempts=1))
    assert (res["stopped_at"] == 1).all()
    assert np.array_equal(np.asarray(res["answers"], np.float64),
                          np.arange(6.0) + 10.0)
    assert (res["scores"] == 0.9).all()
    assert res["resilience"]["fallback_answers"] == 6
    # every tier down: nothing was ever scored -> accounted shed
    specs = [FaultSpec(error_rate=1.0, seed=4),
             FaultSpec(error_rate=1.0, seed=5),
             FaultSpec(error_rate=1.0, seed=6)]
    res = execute_cascade(
        wrap_tiers(_mk_tiers(), specs), [0.5, 0.5], _scorer,
        np.arange(6.0), batch_size=3, retry=RetryPolicy(max_attempts=1))
    assert (res["stopped_at"] == -2).all()
    assert (res["cost"] == 0.0).all()
    assert res["resilience"]["shed"] == 6


def test_offline_shared_tier_health_skips_open_tier():
    """A live TierHealth shared across calls: the first call trips tier
    b's breaker; the second call starts with it open and never invokes
    it at all."""
    health = TierHealth(3, BreakerConfig(window=4, fail_rate=0.5,
                                         min_samples=1, cooldown_s=1e9))
    clk = _FakeClock()
    specs = [None, FaultSpec(error_rate=1.0, seed=7), None]
    execute_cascade(wrap_tiers(_mk_tiers(), specs), [0.5, 0.5], _scorer,
                    np.arange(4.0), batch_size=4,
                    retry=RetryPolicy(max_attempts=1), breaker=health,
                    clock=clk, sleep=clk.sleep)
    assert health.trips == 1
    tiers = _mk_tiers()
    counted = FaultyTier(tiers[1], FaultSpec())    # inert wrapper counts
    tiers[1] = counted
    res = execute_cascade(tiers, [0.5, 0.5], _scorer, np.arange(4.0),
                          batch_size=4, breaker=health,
                          clock=clk, sleep=clk.sleep)
    assert counted.calls == 0                      # skipped outright
    assert res["resilience"]["failovers"] == 4
    assert (res["stopped_at"] == 2).all()
    # size mismatch is an error, not silent misrouting
    with pytest.raises(ValueError, match="TierHealth"):
        execute_cascade(_mk_tiers()[:2], [0.5], _scorer, np.arange(2.0),
                        breaker=health)


def test_offline_zero_faults_bit_identical():
    """Retry + breaker wired but nothing fails: every output is
    bit-identical to the plain executor."""
    q = np.arange(16.0)
    ref = execute_cascade(_mk_tiers(), [0.5, 0.5], _scorer, q, batch_size=4)
    assert "resilience" not in ref
    res = execute_cascade(_mk_tiers(), [0.5, 0.5], _scorer, q, batch_size=4,
                          retry=RetryPolicy(), breaker=BreakerConfig())
    for k in ("answers", "cost", "stopped_at"):
        assert np.array_equal(np.asarray(ref[k]), np.asarray(res[k])), k
    assert np.array_equal(ref["scores"], res["scores"], equal_nan=True)
    assert ref["tier_counts"] == res["tier_counts"]
    assert ref["accepted_counts"] == res["accepted_counts"]
    r = res["resilience"]
    assert r["retries"] == 0 and r["failovers"] == 0 and r["trips"] == 0


# ---------------------------------------------------------------------------
# parallel scheduler failover (repro.serving.sched)
# ---------------------------------------------------------------------------


def _toy_pipeline(n_tiers=2, faults=None, retry=None, breaker=None,
                  batch_size=8, answer_hook=None):
    """The test_sched toy marketplace: even leading token accepts at
    tier 0, odd escalates; middle tiers (n_tiers=3) score 0.1 too."""
    def mk(v):
        def answer(t):
            if answer_hook is not None:
                answer_hook(v, t)
            return np.full(len(t), v, np.int32)
        return answer

    tiers = [TierSpec(f"t{j}", mk(j), ApiCost(10.0 * 3 ** j,
                                              10.0 * 3 ** j, 0.0),
                      prompt=PromptSpec(tuple(range(j + 1)), 100, 40))
             for j in range(n_tiers)]

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    return ServingPipeline(
        tiers=tiers, thresholds=[0.5] * (n_tiers - 1), scorer=scorer,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size,
        faults=faults, retry=retry, breaker=breaker)


def _tokens(n):
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)
    return toks


def test_scheduler_transient_faults_absorbed_by_retry():
    """Transient errors + a generous retry budget: the trace completes
    with the exact answers of a clean run, and the retries are visible
    in the resilience telemetry."""
    toks = _tokens(24)
    clean = TierScheduler(_toy_pipeline(), max_chunk=4).run_trace(toks)
    pol = RetryPolicy(max_attempts=8, backoff_s=0.0005)
    faults = [FaultSpec(error_rate=0.5, timeout_rate=0.2, seed=11), None]
    sched = TierScheduler(_toy_pipeline(faults=faults, retry=pol),
                          max_chunk=4, slo=SLOConfig(retry=pol))
    res = sched.run_trace(toks)
    assert np.array_equal(clean.answers, res.answers)
    assert (clean.cost == res.cost).all()
    r = res.ingress["resilience"]
    assert r["retries"] > 0
    assert r["faults_injected"]["t0"]["error"] > 0
    assert "resilience:" in res.summary()
    # the clean scheduler reports no resilience block at all
    assert clean.ingress["resilience"] is None


def test_scheduler_outage_trips_breaker_and_fails_over():
    """The acceptance scenario: a sustained mid-tier outage under a
    Poisson trace — the breaker trips, rows escalate past the sick tier,
    every request resolves, zero crashed workers."""
    toks = _tokens(24)
    arrivals = np.linspace(0.0, 0.01, 24)
    slo = SLOConfig(retry=RetryPolicy(max_attempts=2, backoff_s=0.0005),
                    breaker=BreakerConfig(window=4, fail_rate=0.5,
                                          min_samples=2, cooldown_s=30.0))
    faults = [None, FaultSpec(error_rate=1.0, seed=7), None]
    sched = TierScheduler(
        _toy_pipeline(n_tiers=3, faults=faults, retry=slo.retry,
                      breaker=slo.breaker),
        max_chunk=8, slo=slo)
    res = sched.run_trace(toks, arrivals)
    assert (res.stopped_at != -1).all()            # every request resolved
    assert set(np.unique(res.stopped_at)) == {0, 2}  # nobody stops at t1
    r = res.ingress["resilience"]
    assert r["trips"] >= 1 and r["failovers"] > 0
    assert r["breakers"][1]["state"] in ("open", "half_open")
    # odd rows answered by tier 2 (value 2), evens by tier 0
    odd = toks[:, 0] % 2 == 1
    assert (res.answers[odd] == 2).all() and (res.answers[~odd] == 0).all()


def test_scheduler_last_tier_failure_degrades_to_fallback():
    """The last tier is down: rows that reach it resolve to their
    best-scoring earlier rejected answer, marked degraded — the trace
    still completes."""
    toks = _tokens(16)
    pol = RetryPolicy(max_attempts=2, backoff_s=0.0005)
    faults = [None, FaultSpec(error_rate=1.0, seed=9)]
    sched = TierScheduler(_toy_pipeline(faults=faults, retry=pol),
                          max_chunk=8, slo=SLOConfig(retry=pol))
    res = sched.run_trace(toks)
    odd = toks[:, 0] % 2 == 1
    assert (res.stopped_at[odd] == 0).all()        # fallback = tier 0
    assert (res.answers[odd] == 0).all()
    assert (res.stopped_at[~odd] == 0).all()       # evens: normal accept
    r = res.ingress["resilience"]
    assert r["fallback_answers"] == int(odd.sum())
    assert res.ingress["degraded"] >= int(odd.sum())


def test_scheduler_every_tier_down_sheds_accountably():
    toks = _tokens(8)
    pol = RetryPolicy(max_attempts=1)
    faults = [FaultSpec(error_rate=1.0, seed=3),
              FaultSpec(error_rate=1.0, seed=4)]
    sched = TierScheduler(_toy_pipeline(faults=faults, retry=pol),
                          max_chunk=8, slo=SLOConfig(retry=pol))
    res = sched.run_trace(toks)
    assert (res.stopped_at == -2).all()
    assert (res.cost == 0.0).all()
    assert res.ingress["resilience"]["shed"] == 8


def test_scheduler_zero_faults_with_dials_bit_identical():
    toks = _tokens(24)
    ref = TierScheduler(_toy_pipeline(), max_chunk=8).run_trace(toks)
    slo = SLOConfig(retry=RetryPolicy(), breaker=BreakerConfig())
    res = TierScheduler(_toy_pipeline(), max_chunk=8, slo=slo).run_trace(toks)
    assert np.array_equal(ref.answers, res.answers)
    assert (ref.cost == res.cost).all()
    assert np.array_equal(ref.stopped_at, res.stopped_at)
    assert ref.tier_counts == res.tier_counts
    r = res.ingress["resilience"]
    assert r["retries"] == 0 and r["failovers"] == 0 and r["trips"] == 0


def test_worker_crash_fails_pending_futures():
    """A non-TierFault tier crash mid-trace must surface promptly: the
    driver raises AND every pending per-request future is failed (not
    left hanging for a consumer awaiting it)."""
    calls = {"n": 0}

    def hook(v, t):
        if v == 1:
            calls["n"] += 1
            if calls["n"] == 2:
                raise ValueError("tier exploded mid-stream")

    async def go():
        pipe = _toy_pipeline(answer_hook=hook, batch_size=4)
        sched = TierScheduler(pipe, max_chunk=4)
        queue = IngressQueue()
        reqs = queue.submit_burst(_tokens(16), with_future=True)
        queue.close()
        with pytest.raises(ValueError, match="exploded"):
            await asyncio.wait_for(sched.serve_async(queue), timeout=30.0)
        # every future is settled — finished rows with results, the rest
        # with the crash exception; none is left pending
        hung = [r for r in reqs if not r.future.done()]
        assert not hung
        failed = [r for r in reqs
                  if r.future.done() and r.future.exception() is not None]
        assert failed, "no future carried the crash"
        for r in failed:
            assert "exploded" in str(r.future.exception())

    asyncio.run(go())


def test_worker_crash_still_fatal_with_resilience_on():
    """Resilience absorbs TierFault only: a programming error in a tier
    still tears the stream down even with retry/breaker dials set."""
    def hook(v, t):
        if v == 0:
            raise KeyError("bug")

    slo = SLOConfig(retry=RetryPolicy(), breaker=BreakerConfig())
    sched = TierScheduler(_toy_pipeline(answer_hook=hook, retry=slo.retry,
                                        breaker=slo.breaker),
                          max_chunk=8, slo=slo)
    with pytest.raises(KeyError):
        sched.run_trace(_tokens(8))


# ---------------------------------------------------------------------------
# speculation EV ranking (sched.policy)
# ---------------------------------------------------------------------------


class _Row:
    def __init__(self, probs):
        self.probs = probs


def test_speculation_ev_math():
    # P(reach) = prod of reject probabilities over [cur, target)
    assert speculation_ev([0.1, 0.2], 0, 2, 2.0) == \
        pytest.approx(0.9 * 0.8 * 2.0)
    assert speculation_ev([0.1, 0.2], 1, 2, 2.0) == pytest.approx(1.6)
    # cold (no router): EV is the bare predicted service time
    assert speculation_ev(None, 0, 2, 0.7) == 0.7


def test_rank_speculation_orders_by_ev_and_keeps_queue_order():
    rows = [_Row([0.9, 0.0]), _Row([0.1, 0.0]),
            _Row([0.5, 0.0]), _Row([0.0, 0.0])]
    # EVs at target=1: 0.1, 0.9, 0.5, 1.0 -> best two are rows 3 and 1,
    # returned in queue order (1 before 3)
    out = rank_speculation(rows, [0, 0, 0, 0], 1, 1.0, cap=2)
    assert out == [rows[1], rows[3]]
    # under-cap: untouched (and not re-ordered)
    assert rank_speculation(rows, [0] * 4, 1, 1.0, cap=4) == rows
    # cold rows all tie -> stable: the first `cap` in queue order
    cold = [_Row(None) for _ in range(4)]
    assert rank_speculation(cold, [0] * 4, 1, 1.0, cap=2) == cold[:2]


# ---------------------------------------------------------------------------
# terminal-failure backoff crediting, virtual clock, fault groups
# ---------------------------------------------------------------------------


def test_on_backoff_fires_before_terminal_failure():
    """The on_backoff hook sees every slept backoff — including the
    ones before a terminal failure, which the returned total (only
    delivered on success) cannot report."""
    clk = _FakeClock()
    pol = RetryPolicy(max_attempts=3, backoff_s=0.1, jitter_frac=0.0)
    tier, calls = _flaky(99)
    waits = []
    with pytest.raises(TransientError):
        invoke_with_retry(tier, np.arange(2.0), pol, clock=clk,
                          sleep=clk.sleep, on_backoff=waits.append)
    assert calls["n"] == 3
    assert waits == pytest.approx([0.1, 0.2])
    assert clk.now == pytest.approx(0.3)


def test_offline_terminal_failure_credits_backoff():
    """Every tier down: the rows shed, but the backoff seconds the
    wasted retries slept still land in the telemetry — they were real
    added latency even though no attempt ever answered."""
    clk = _FakeClock()
    specs = [FaultSpec(error_rate=1.0, seed=21),
             FaultSpec(error_rate=1.0, seed=22),
             FaultSpec(error_rate=1.0, seed=23)]
    pol = RetryPolicy(max_attempts=2, backoff_s=0.05, jitter_frac=0.0)
    res = execute_cascade(wrap_tiers(_mk_tiers(), specs), [0.5, 0.5],
                          _scorer, np.arange(4.0), batch_size=4,
                          retry=pol, clock=clk, sleep=clk.sleep)
    assert (res["stopped_at"] == -2).all()
    r = res["resilience"]
    assert r["retries"] == 3                    # one wasted retry per tier
    assert r["backoff_s"] == pytest.approx(3 * 0.05)
    assert clk.now == pytest.approx(r["backoff_s"])


def test_virtual_clock_unit():
    vc = VirtualClock()
    assert vc() == 0.0
    vc.sleep(0.25)
    vc.advance(0.05)
    assert vc() == pytest.approx(0.30)
    vc.sleep(-1.0)                              # time never runs backwards
    assert vc() == pytest.approx(0.30)
    assert VirtualClock(start=2.0)() == 2.0


def test_pipeline_serve_virtual_clock_no_wall_sleep():
    """Batch serve under a VirtualClock: answers and charged cost match
    the clean run bit-for-bit, backoff advances *virtual* time, and the
    wall clock never pays for it."""
    import time as _t
    toks = _tokens(16)
    clean = _toy_pipeline().serve(toks)
    faults = [FaultSpec(error_rate=0.5, seed=31), None]
    pol = RetryPolicy(max_attempts=8, backoff_s=0.2, jitter_frac=0.0)
    vc = VirtualClock()
    pipe = _toy_pipeline(faults=faults, retry=pol)
    t0 = _t.perf_counter()
    res = pipe.serve(toks, clock=vc, sleep=vc.sleep)
    wall = _t.perf_counter() - t0
    assert np.array_equal(clean.answers, res.answers)
    assert (clean.cost == res.cost).all()
    r = res.ingress["resilience"]
    assert r["retries"] > 0
    assert vc() == pytest.approx(r["backoff_s"])
    assert r["backoff_s"] >= 0.2                # would have wall-slept
    assert wall < r["backoff_s"]                # ... but did not
    assert "resilience:" in res.summary()
    assert clean.ingress is None                # clean batch: no block


def test_fault_spec_group_parse_and_field():
    sp = FaultSpec.parse("error=0.2,group=upstream,seed=3")
    assert sp.group == "upstream" and sp.error_rate == 0.2 and sp.seed == 3
    assert FaultSpec().group is None


def test_fault_group_broadcast_correlated():
    """Grouped broadcast: every tier shares the seed, so draw-based
    faults hit the same invoke indices (one upstream, one schedule);
    the ungrouped broadcast keeps the per-tier seed offsets and
    decorrelates."""
    def pattern(spec):
        out = []
        for ft in wrap_tiers(_mk_tiers(), spec):
            seq = []
            for _ in range(20):
                try:
                    ft.invoke(np.arange(2.0))
                    seq.append(0)
                except TierFault:
                    seq.append(1)
            out.append(seq)
        return out

    corr = pattern(FaultSpec(error_rate=0.4, seed=5, group="upstream"))
    assert corr[0] == corr[1] == corr[2]
    indep = pattern(FaultSpec(error_rate=0.4, seed=5))
    assert indep[0] != indep[1]


def test_fault_group_list_adopts_first_members_seed():
    specs = [FaultSpec(error_rate=0.3, seed=1, group="u"),
             FaultSpec(error_rate=0.3, seed=99, group="u"),
             FaultSpec(error_rate=0.3, seed=42)]
    tiers = wrap_tiers(_mk_tiers(), specs)
    assert tiers[0].spec.seed == 1 and tiers[1].spec.seed == 1
    assert tiers[2].spec.seed == 42             # ungrouped: untouched


def test_breaker_fleet_survives_correlated_outage():
    """Regression for the correlated-failure scenario the independent
    model can't produce: one upstream outage takes tiers a AND b down
    together. Both breakers trip, every row fails over to the
    independent tier c, nothing sheds."""
    clk = _FakeClock()                          # t=0: inside the window
    specs = [FaultSpec(outage=(0.0, 50.0), group="u", seed=8),
             FaultSpec(outage=(0.0, 50.0), group="u", seed=8),
             None]
    res = execute_cascade(
        wrap_tiers(_mk_tiers(), specs, clock=clk, sleep=clk.sleep),
        [0.5, 0.5], _scorer, np.arange(8.0), batch_size=2,
        retry=RetryPolicy(max_attempts=1),
        breaker=BreakerConfig(window=4, fail_rate=0.5, min_samples=2,
                              cooldown_s=100.0),
        clock=clk, sleep=clk.sleep)
    assert (res["stopped_at"] == 2).all()
    r = res["resilience"]
    assert r["trips"] == 2 and r["shed"] == 0
    assert r["breakers"][0]["state"] == "open"
    assert r["breakers"][1]["state"] == "open"
