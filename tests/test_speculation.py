"""Speculative cascade execution (ISSUE 7): the split engine entry
points, the pool's speculative-future tracking, the speculation policy
units, and the scheduler end to end.

The contract:

  * ``generate`` IS ``decode_from(prefill_async(...))`` — the split is
    bit-identical by construction, greedy or sampled;
  * a ``PrefillFuture`` resolves exactly once: commit (KV handoff into
    the decode loop) or cancel (device references dropped, never
    charged) — double resolution raises;
  * ``EnginePool.speculate/commit/cancel/cancel_all`` track in-flight
    futures per (tier, placement) engine and count issue/commit/cancel;
  * the policy layer gates candidates on the router's per-tier accept
    probabilities (cold fallback: everything qualifies) and the idle
    budget *leading* (predicted service counts before issue);
  * a speculative stream is bit-identical to the non-speculative one —
    answers, charged cost, stopped_at, tier_counts — with the
    commit/cancel split surfaced in telemetry. (The full placement x
    compaction matrix and the cancellation edge cases live in
    tests/test_placement.py.)
"""
import time

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.models import transformer as T
from repro.serving.engine import EnginePool, GenerationEngine
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.sched import SLOConfig, may_speculate, speculation_candidate


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _toks(b=3, s=5, seed=1):
    return (np.random.default_rng(seed)
            .integers(1, 200, size=(b, s)).astype(np.int32))


# ---------------------------------------------------------------------------
# the split engine entry points
# ---------------------------------------------------------------------------


def test_split_matches_generate_greedy(small_model):
    cfg, params = small_model
    eng = GenerationEngine(cfg, params)
    toks = _toks()
    ref = eng.generate(toks, n_new=4)
    fut = eng.prefill_async(toks, n_new=4)
    assert fut.live and fut.b == 3 and fut.n_new == 4
    out = eng.decode_from(fut)
    assert np.array_equal(out, ref)
    assert fut.consumed and not fut.live


def test_split_matches_generate_sampled(small_model):
    """Temperature sampling threads the PRNG state through the future —
    same seed, same tokens on both halves of the split."""
    cfg, params = small_model
    eng = GenerationEngine(cfg, params, temperature=0.8)
    toks = _toks(seed=2)
    ref = eng.generate(toks, n_new=4, seed=9)
    out = eng.decode_from(eng.prefill_async(toks, n_new=4, seed=9))
    assert np.array_equal(out, ref)
    # a different seed genuinely diverges (the sampling path is live)
    other = eng.decode_from(eng.prefill_async(toks, n_new=4, seed=10))
    assert not np.array_equal(out, other)


def test_future_resolves_exactly_once(small_model):
    cfg, params = small_model
    eng = GenerationEngine(cfg, params)
    toks = _toks()
    # cancel retires the device references; decode after cancel raises
    fut = eng.prefill_async(toks, n_new=2)
    fut.cancel()
    assert fut.cancelled and not fut.live
    assert fut._cache is None and fut._tok is None
    with pytest.raises(RuntimeError, match="cancelled"):
        eng.decode_from(fut)
    fut.cancel()                              # idempotent
    # double consume raises
    fut2 = eng.prefill_async(toks, n_new=2)
    eng.decode_from(fut2)
    with pytest.raises(RuntimeError, match="consumed"):
        eng.decode_from(fut2)
    fut2.cancel()                             # no-op after consume
    assert not fut2.cancelled
    # a future only commits on the engine that issued it
    fut3 = eng.prefill_async(toks, n_new=2)
    with pytest.raises(ValueError, match="different engine"):
        GenerationEngine(cfg, params).decode_from(fut3)
    fut3.cancel()


def test_future_empty_decode(small_model):
    cfg, params = small_model
    eng = GenerationEngine(cfg, params)
    out = eng.decode_from(eng.prefill_async(_toks(), n_new=0))
    assert out.shape == (3, 0) and out.dtype == np.int32


# ---------------------------------------------------------------------------
# pool tracking
# ---------------------------------------------------------------------------


def test_pool_speculate_commit_cancel(small_model):
    cfg, params = small_model
    pool = EnginePool()
    toks = _toks()
    ref = pool.get(cfg, params).generate(toks, n_new=3)
    f1 = pool.speculate(cfg, params, toks, n_new=3)
    f2 = pool.speculate(cfg, params, toks, n_new=3)
    assert pool.inflight() == 2
    assert np.array_equal(pool.commit(f1), ref)   # commit == generate
    assert pool.inflight() == 1                   # commit untracks
    pool.cancel(f2)
    assert pool.inflight() == 0
    pool.cancel(f2)                               # idempotent, not counted
    assert pool.spec_stats == {"issued": 2, "committed": 1, "cancelled": 1}
    with pytest.raises(RuntimeError, match="retired"):
        pool.commit(f2)


def test_pool_cancel_all_scopes_by_engine(small_model):
    cfg, params = small_model
    pool = EnginePool()
    dev = jax.local_devices()[0]
    toks = _toks()
    f_shared = pool.speculate(cfg, params, toks, n_new=2)
    f_pinned = pool.speculate(cfg, params, toks, n_new=2, device=dev)
    assert pool.inflight() == 2
    # scoped cancel: only the pinned engine's speculation retires
    assert pool.cancel_all(cfg, params, device=dev) == 1
    assert f_pinned.cancelled and f_shared.live
    assert pool.inflight() == 1
    # blanket cancel sweeps the rest
    assert pool.cancel_all() == 1
    assert not f_shared.live and pool.inflight() == 0
    assert pool.spec_stats["cancelled"] == 2


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_speculation_candidate_rules():
    # cold router: everything qualifies
    assert speculation_candidate(None, 0, 2, 0.5)
    probs = np.array([0.1, 0.2, 0.9])
    # every intermediate tier predicted to reject -> qualify
    assert speculation_candidate(probs, 0, 2, 0.5)
    # a predicted accept anywhere in [cur, target) kills the candidate
    assert not speculation_candidate(probs, 1, 3, 0.5)
    assert not speculation_candidate(probs, 0, 3, 0.5)
    # the bar is strict: prob == bar counts as predicted accept
    assert not speculation_candidate(np.array([0.5]), 0, 1, 0.5)


def test_may_speculate_budget_gate():
    off = SLOConfig()
    assert not may_speculate(off, 0.0, 10.0)          # opt-in only
    unlimited = SLOConfig(speculate=True, spec_idle_frac=None)
    assert may_speculate(unlimited, 1e9, 1.0)
    slo = SLOConfig(speculate=True, spec_idle_frac=0.5)
    assert may_speculate(slo, 0.4, 1.0)               # under budget
    assert not may_speculate(slo, 0.6, 1.0)           # over budget
    # the gate is *leading*: predicted service counts before issue
    assert not may_speculate(slo, 0.4, 1.0, predicted_s=0.2)
    assert may_speculate(slo, 0.4, 1.0, predicted_s=0.05)


def test_slo_speculation_validation():
    with pytest.raises(ValueError, match="spec_depth"):
        SLOConfig(spec_depth=0)
    with pytest.raises(ValueError, match="spec_bar"):
        SLOConfig(spec_bar=1.5)
    with pytest.raises(ValueError, match="spec_idle_frac"):
        SLOConfig(spec_idle_frac=0.0)
    SLOConfig(speculate=True, spec_depth=3, spec_bar=0.0,
              spec_idle_frac=None)                    # all valid knobs


# ---------------------------------------------------------------------------
# scheduler end to end: mixed accept/escalate traffic — some
# speculations commit, some cancel, everything bit-identical
# ---------------------------------------------------------------------------


def _mixed_pipeline(delay=0.08):
    """3 tiers, slow invokes; rows with even leading token accept at
    tier 0, multiples of 3 at tier 1, the rest escalate to the top."""
    tiers = [TierSpec(f"t{j}",
                      (lambda t, j=j: (time.sleep(delay),
                                       t[:, 0].astype(np.int64) * 10 + j)[1]),
                      ApiCost(10.0 * 3 ** j, 10.0 * 3 ** j, 0.0),
                      prompt=PromptSpec(tuple(range(j + 1)), 100, 40))
             for j in range(3)]

    def scorer(t, a):
        lead = t[:, 0]
        return np.where(lead % 2 == 0, 0.9,
                        np.where(lead % 3 == 0, 0.6, 0.1))

    return ServingPipeline(tiers=tiers, thresholds=[0.8, 0.5],
                           scorer=scorer, full_prompt_tokens=840,
                           pad_token=-1, batch_size=8)


def test_scheduler_speculation_bit_identical_mixed():
    toks = np.zeros((12, 4), np.int32)
    toks[:, 0] = np.arange(12)
    slo = SLOConfig(max_holdback_s=0.005, speculate=True, spec_depth=2,
                    spec_idle_frac=None)
    ref = _mixed_pipeline().serve_stream(toks, parallel=True)
    res = _mixed_pipeline().serve_stream(toks, parallel=True, slo=slo)
    assert np.array_equal(ref.answers, res.answers)
    assert (ref.cost == res.cost).all()               # charged cost exact
    assert np.array_equal(ref.stopped_at, res.stopped_at)
    assert ref.tier_counts == res.tier_counts
    spec = res.ingress["speculation"]
    # mixed traffic: escalating rows commit, accepted rows cancel
    assert spec["committed"] > 0 and spec["cancelled"] > 0
    assert spec["issued"] == spec["committed"] + spec["cancelled"]
    assert spec["wasted_s"] > 0.0
    assert "speculation:" in res.summary()


def test_scheduler_speculation_respects_idle_budget():
    """A tiny idle budget throttles speculative issue without breaking
    bit-identity: the gate only decides whether to burn idle cycles."""
    toks = np.zeros((12, 4), np.int32)
    toks[:, 0] = np.arange(12)
    slo = SLOConfig(max_holdback_s=0.005, speculate=True, spec_depth=2,
                    spec_idle_frac=1e-6, init_service_s=0.05)
    ref = _mixed_pipeline().serve_stream(toks, parallel=True)
    res = _mixed_pipeline().serve_stream(toks, parallel=True, slo=slo)
    assert np.array_equal(ref.answers, res.answers)
    assert (ref.cost == res.cost).all()
    spec = res.ingress["speculation"]
    # the gate is *leading*: the cold-start service guess alone blows
    # the near-zero budget, so not even a first probe is issued — no
    # wasted device-seconds ever accrue
    assert spec["issued"] == 0
    assert spec["wasted_s"] == 0.0
