"""Explicit shard_map collectives vs single-device oracles (runs in a
subprocess with 8 host devices so this process keeps 1 device)."""
import os
import subprocess
import sys


def test_shard_map_flash_decode_and_expert_ffn():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.sharding.shard_map_ops import flash_decode_sharded, expert_parallel_ffn
from repro.kernels.decode_attention.ref import decode_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
B, S, KVH, G, D = 2, 64, 2, 2, 16
q = jax.random.normal(key, (B, KVH, G, D))
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, D))
v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, D))
with mesh:
    o = flash_decode_sharded(q, k, v, 40, mesh, seq_axis="model")
r = decode_ref(q, k, v, 40)
err = float(jnp.abs(o - r).max() / (jnp.abs(r).max() + 1e-9))
assert err < 1e-5, f"flash_decode err {err}"

E, C, d, f = 4, 8, 16, 32
xg = jax.random.normal(key, (B, E, C, d))
wg = jax.random.normal(jax.random.PRNGKey(3), (E, d, f))
wu = jax.random.normal(jax.random.PRNGKey(4), (E, d, f))
wd = jax.random.normal(jax.random.PRNGKey(5), (E, f, d))
with mesh:
    y = expert_parallel_ffn(xg, wg, wu, wd, mesh, expert_axis="model")
h = jax.nn.silu(jnp.einsum("becd,edf->becf", xg, wg)) * jnp.einsum(
    "becd,edf->becf", xg, wu)
ref = jnp.einsum("becf,efd->becd", h, wd)
err = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
assert err < 1e-5, f"expert_ffn err {err}"
print("SHARD-MAP-OPS-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARD-MAP-OPS-OK" in out.stdout, out.stderr[-3000:]
