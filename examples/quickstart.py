"""Quickstart: learn a FrugalGPT cascade on the (simulated) HEADLINES
marketplace and print the cost/accuracy outcome.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cascade import evaluate_offline
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import simulate_market, simulate_scores, split_market


def main():
    # 1. the LLM marketplace: 12 APIs, Table-1 prices, paper-calibrated
    data = simulate_market("HEADLINES", seed=0)
    scores = simulate_scores(data, seed=1)            # g(q, a) reliability
    tr, te, str_, ste = split_market(data, scores, 0.5, seed=2)

    accs = np.asarray(data.accuracy())
    g4 = data.names.index("GPT-4")
    print("marketplace accuracy:")
    for n, a in sorted(zip(data.names, accs), key=lambda x: -x[1]):
        print(f"  {n:10s} {a:.3f}")

    # 2. learn the cascade under a budget = 1/5 of GPT-4's cost
    budget = float(data.cost[:, g4].mean()) / 5
    cascade, _ = learn_cascade(tr, str_, budget, RouterConfig())
    print(f"\nlearned cascade: {cascade.describe(data.names)}")

    # 3. evaluate on held-out queries
    m = evaluate_offline(cascade, te, ste)
    g4_cost = float(te.cost[:, g4].mean())
    print(f"accuracy: {m['acc']:.3f} (GPT-4 alone: {accs[g4]:.3f})")
    print(f"avg cost: ${m['avg_cost']:.5f} vs GPT-4 ${g4_cost:.5f} "
          f"-> {100*(1-m['avg_cost']/g4_cost):.0f}% saved")


if __name__ == "__main__":
    main()
