"""End-to-end driver: serve a batched request stream through the unified
FrugalGPT pipeline — completion cache + prompt adaptation + a *real*
model cascade, all on one request path.

Thin wrapper over ``repro.serving.build_pipeline``: train 3 tier models
of different capacity on the synthetic HEADLINES task, collect offline
marketplace data, train the DistilBERT-analogue scorer, greedily select
per-tier prompts, learn (L, tau) with the router optimizer, then serve
request batches tier-by-tier with compaction. A second pass over a
repetition-heavy stream shows the completion cache absorbing traffic.

Run: PYTHONPATH=src python examples/cascade_serving.py [--requests 400]
     PYTHONPATH=src python examples/cascade_serving.py --stream \\
         [--rate 500]     # continuous batching over a Poisson trace
"""
import argparse

import numpy as np

from repro.data import synthetic
from repro.serving import BuildConfig, build_pipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--train-queries", type=int, default=400)
    ap.add_argument("--stream", action="store_true",
                    help="also replay a Poisson arrival trace through "
                         "the continuous batcher (async ingress)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="stream mode: mean arrival rate (requests/s)")
    args = ap.parse_args()

    # small 3-tier marketplace so the example runs in minutes on CPU
    pipe, _ = build_pipeline(BuildConfig(
        tiers=("GPT-J", "ChatGPT", "GPT-4"), train_steps_cap=200,
        train_queries=args.train_queries, scorer_steps=250))

    print("== serving ==")
    test = synthetic.sample("headlines", args.requests, seed=77)
    res = pipe.serve(test.tokens)
    acc = float((res.answers == test.labels).mean())
    print(res.summary())
    print(f"accuracy {acc:.3f}; avg cost ${res.cost.mean():.6f} "
          f"({100 * res.savings_frac:.0f}% cheaper than top-tier-only)")

    print("== serving a repetition-heavy stream (cache at work) ==")
    idx = np.random.default_rng(3).integers(0, args.requests,
                                            size=args.requests)
    res2 = pipe.serve(test.tokens[idx])
    acc2 = float((res2.answers == test.labels[idx]).mean())
    print(res2.summary())
    print(f"accuracy {acc2:.3f}; avg cost ${res2.cost.mean():.6f} "
          f"({100 * res2.savings_frac:.0f}% cheaper than top-tier-only)")

    if args.stream:
        from repro.serving.ingress import poisson_arrivals

        print("== continuous batching over a Poisson arrival trace ==")
        print("   (parallel tier scheduler: tiers decode concurrently; "
              "see examples/slo_streaming.py for deadlines/overload)")
        arrivals = poisson_arrivals(args.requests, args.rate, seed=9)
        res3 = pipe.serve_stream(test.tokens, arrivals, max_chunk=32)
        acc3 = float((res3.answers == test.labels).mean())
        print(res3.summary())
        print(f"accuracy {acc3:.3f}; trace span {arrivals[-1]:.2f}s, "
              f"drained in {res3.latency['total']:.2f}s")


if __name__ == "__main__":
    main()
