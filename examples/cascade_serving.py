"""End-to-end driver: serve a batched request stream through a *real*
model cascade (the paper's LLM cascade as a serving-system policy).

Pipeline: train 3 tier models of different capacity on the synthetic
HEADLINES task -> collect offline marketplace data -> train the
DistilBERT-analogue scorer -> learn (L, tau) with the router optimizer ->
serve a fresh request batch tier-by-tier with compaction.

Run: PYTHONPATH=src python examples/cascade_serving.py [--requests 400]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import neural_market as NM
from repro.core import scorer as SC
from repro.core.router import RouterConfig, learn_cascade
from repro.data import synthetic
from repro.serving.engine import CascadeServer, Tier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--train-queries", type=int, default=400)
    args = ap.parse_args()

    # small 3-tier marketplace so the example runs in minutes on CPU
    NM.TIERS = {k: v for k, v in NM.TIERS.items()
                if k in ("GPT-J", "ChatGPT", "GPT-4")}
    for k in NM.TIERS:
        NM.TIERS[k]["steps"] = min(NM.TIERS[k]["steps"], 200)

    print("== training tier models ==")
    apis = NM.train_marketplace("headlines", seed=0, verbose=True)

    print("== collecting offline marketplace data ==")
    train = synthetic.sample("headlines", args.train_queries, seed=11)
    data, answers = NM.collect_market_data(apis, train.tokens, train.labels)
    print("tier accuracy:", {n: round(float(a), 3)
                             for n, a in zip(data.names,
                                             np.asarray(data.accuracy()))})

    print("== training the scoring function g(q, a) ==")
    k = len(apis)
    q = np.repeat(train.tokens, k, axis=0)
    a = answers.reshape(-1)
    y = np.asarray(data.correct).reshape(-1)
    sp = SC.train_scorer(q, a, y, steps=250)
    s_train = np.stack([SC.score(sp, train.tokens, answers[:, j])
                        for j in range(k)], axis=1)
    print(f"scorer AUC: {SC.auc(s_train.reshape(-1), y):.3f}")

    print("== learning the cascade ==")
    budget = float(data.cost[:, -1].mean()) * 0.3   # 30% of the top tier
    cas, m = learn_cascade(data, jnp.asarray(s_train), budget,
                           RouterConfig(top_lists=10, sample=256))
    print(f"cascade: {cas.describe(data.names)}")
    print(f"train: acc={m['acc']:.3f} avg_cost=${m['avg_cost']:.6f}")

    print("== serving ==")
    test = synthetic.sample("headlines", args.requests, seed=77)
    tiers = [Tier(apis[i].name, apis[i].answer, apis[i].query_cost)
             for i in cas.apis]
    server = CascadeServer(tiers, cas.thresholds,
                           lambda t, ans: SC.score(sp, t, ans))
    res = server.serve(test.tokens)
    acc = float((res["answers"] == test.labels).mean())
    top_cost = apis[-1].query_cost(test.tokens).mean()
    print(f"served {args.requests} requests in {res['latency_s']:.1f}s; "
          f"tier batch sizes: {res['tier_counts']}")
    print(f"accuracy {acc:.3f}; avg cost ${res['cost'].mean():.6f} "
          f"({100*(1-res['cost'].mean()/top_cost):.0f}% cheaper than "
          f"top-tier-only)")


if __name__ == "__main__":
    main()
