"""Contextual entry routing + online budget governance
(``repro.serving.strategy``) on a toy 3-tier marketplace — no model
training, runs in seconds on CPU.

Three acts over the same pipeline:

  1. fixed cascade        — every query enters at tier 0 and climbs;
     hard queries pay the cheap tiers just to fail on them;
  2. contextual routing   — an entry router trained on the (feature,
     accept) pairs the offline build would produce sends confidently-
     hard queries straight past the dead-weight tiers: same answers,
     fewer tier calls, lower cost;
  3. budget governor      — the traffic mix hardens mid-stream; the
     governor notices the realized $/query drifting over target and
     shifts the cascade thresholds + entry bar window by window until
     spend is back on budget.

Run: PYTHONPATH=src python examples/contextual_routing.py
"""
import numpy as np

from repro.core.cost import ApiCost
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    ServingStrategy, train_entry_router)

D = 8                       # feature width (stands in for the scorer
                            # encoder embedding the real builder uses)


def build_pipeline(strategy=None) -> ServingPipeline:
    """3-tier toy marketplace. The leading feature IS the (negated)
    difficulty: reliability scores fall continuously as it drops, so
    the cascade thresholds are a smooth cost/accuracy dial."""
    prices = [ApiCost(10.0, 10.0, 0.001),      # per-request fees make the
              ApiCost(100.0, 100.0, 0.002),    # cheap probes worth skipping
              ApiCost(1000.0, 1000.0, 0.0)]
    tiers = [TierSpec(f"tier{j}",
                      (lambda t, j=j: np.full(len(t), j, np.int32)),
                      prices[j]) for j in range(3)]

    def scorer(t, a):
        # tier 1 is a stronger model: same query scores higher there
        shift = np.where(a == 0, 0.0, 1.2)
        return 1.0 / (1.0 + np.exp(-1.5 * (t[:, 0] + shift)))

    return ServingPipeline(
        tiers=tiers, thresholds=[0.7, 0.5], scorer=scorer,
        embed=lambda t: np.asarray(t[:, :D], np.float32),
        full_prompt_tokens=200, pad_token=-1, batch_size=32,
        strategy=strategy)


def train_router(seed: int = 0) -> ContextualRouter:
    """What the builder does from offline MarketData, in miniature:
    features -> per-position accept labels -> a small jax MLP."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(800, D)).astype(np.float32)
    # accept labels implied by the toy scorer at the base thresholds:
    # sigmoid(1.5 x) >= 0.7 at tier 0, sigmoid(1.5 (x + 1.2)) >= 0.5 at 1
    labels = np.stack([emb[:, 0] > 0.565, emb[:, 0] > -1.2,
                       np.ones(800, bool)], axis=1).astype(np.float32)
    return ContextualRouter(train_entry_router(emb, labels, steps=250,
                                               seed=seed), 3)


def queries(n: int, hardness: float, seed: int) -> np.ndarray:
    """Feature rows whose leading column (difficulty driver) is shifted
    by ``hardness`` — higher = more escalation = more spend."""
    rng = np.random.default_rng(seed)
    toks = rng.normal(size=(n, D)).astype(np.float32)
    toks[:, 0] -= hardness
    return toks


def main():
    router = train_router()

    # -- act 1 vs act 2: fixed cascade vs contextual entry -----------------
    toks = queries(512, hardness=0.5, seed=1)
    res_fix = build_pipeline().serve(toks)
    strat = ServingStrategy(router=router, entry_bar=0.3)
    res_ctx = build_pipeline(strategy=strat).serve(toks)
    print("== fixed cascade ==")
    print(res_fix.summary())
    print("== contextual entry routing ==")
    print(res_ctx.summary())
    print(f"-> tier-0 calls {res_fix.tier_counts[0]} -> "
          f"{res_ctx.tier_counts[0]} (entries "
          f"{res_ctx.strategy['entry_hist']}); cost "
          f"${res_fix.cost.sum():.4f} -> ${res_ctx.cost.sum():.4f} "
          f"({100 * (1 - res_ctx.cost.sum() / res_fix.cost.sum()):.1f}% "
          f"saved)\n")

    # -- act 3: the governor rides out a hardness drift --------------------
    target = float(res_ctx.cost.mean())        # calm-mix spend = the budget
    gov = BudgetGovernor(target, (0.7, 0.5), base_bar=0.3, window=64,
                         eta=0.3, max_shift=0.6)
    pipe = build_pipeline(strategy=ServingStrategy(
        router=router, governor=gov, entry_bar=0.3))
    print("== budget governor vs a hardening mix "
          f"(target ${target:.6f}/q) ==")
    for step in range(8):
        hardness = 0.5 + 0.12 * step           # the mix drifts harder
        res = pipe.serve(queries(256, hardness, seed=10 + step))
        g = res.strategy["governor"]
        print(f"  step {step}: hardness {hardness:.2f} | window rate "
              f"${np.mean([w['window_rate'] for w in g['trace'][-4:]]):.6f}"
              f" | shift {g['shift']:+.3f} | thresholds "
              f"{tuple(round(t, 2) for t in g['thresholds'])}")
    realized = gov.realized_rate()
    print(f"-> lifetime realized ${realized:.6f}/q vs target "
          f"${target:.6f}/q ({100 * (realized / target - 1):+.1f}%)")


if __name__ == "__main__":
    main()
