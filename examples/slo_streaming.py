"""SLO-aware streaming: deadlines, adaptive holdback, and overload
policies on the parallel tier scheduler (``repro.serving.sched``).

Serves three Poisson traces through the same 2-tier toy marketplace
(no model training, runs in seconds on CPU):

  1. comfortable load, loose deadline  — everything hits its SLO and
     chunks coalesce under the adaptive holdback;
  2. comfortable load, tight deadline  — partial chunks ship early so
     the head-of-line request's predicted completion stays inside its
     deadline (throughput traded for latency);
  3. 4x overload, bounded queues       — the ``degrade`` policy answers
     what it can from the cheapest tier and sheds the rest, keeping
     queues bounded instead of melting down (the paper's cost/accuracy
     dial applied to load).

Run: PYTHONPATH=src python examples/slo_streaming.py
"""
import time

import numpy as np

from repro.core.cost import ApiCost
from repro.serving.ingress import poisson_arrivals
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.sched import SLOConfig, TierScheduler

SERVICE_S = 0.01              # emulated per-chunk decode time


def build_pipeline(max_chunk: int) -> ServingPipeline:
    """2-tier toy marketplace: even leading token is easy (tier 0
    accepts), odd escalates to the pricey tier."""

    def mk_tier(v):
        def answer(t):
            time.sleep(SERVICE_S)
            return np.full(len(t), v, np.int32)
        return answer

    return ServingPipeline(
        tiers=[TierSpec("cheap", mk_tier(0), ApiCost(10.0, 10.0, 0.0)),
               TierSpec("pricey", mk_tier(1), ApiCost(100.0, 100.0, 0.0))],
        thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] % 2 == 0, 0.9, 0.1),
        full_prompt_tokens=840, pad_token=-1, batch_size=max_chunk)


def run(name: str, n: int, rate: float, slo: SLOConfig, max_chunk: int = 8):
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)
    arrivals = poisson_arrivals(n, rate, seed=11)
    pipe = build_pipeline(max_chunk)
    pipe.serve(toks[:max_chunk])           # warm the cost-model jits
    res = TierScheduler(pipe, max_chunk=max_chunk, slo=slo).run_trace(
        toks, arrivals)
    ing = res.ingress
    print(f"-- {name} ({rate:.0f} req/s over {arrivals[-1]:.2f}s) --")
    print(res.summary())
    served = int((res.stopped_at != -2).sum())
    print(f"   served {served}/{n}; chunks/tier {ing['chunks_per_tier']}; "
          f"queue peaks {ing['queue_peak']}; "
          f"service EWMA {[round(s * 1e3, 1) for s in ing['service_ewma_s']]}ms\n")
    return res


def main():
    # service rate ~ max_chunk / SERVICE_S = 800/s per tier
    easy = SLOConfig(deadline_s=0.5, max_holdback_s=0.05)
    run("loose deadline", n=160, rate=400, slo=easy)

    tight = SLOConfig(deadline_s=0.03, max_holdback_s=0.05,
                      init_service_s=SERVICE_S)
    res = run("tight 30ms deadline", n=160, rate=400, slo=tight)
    assert res.ingress["deadline_hit_rate"] is not None

    overload = SLOConfig(deadline_s=0.1, max_holdback_s=0.002,
                         queue_cap=16, overload="degrade")
    res = run("4x overload, degrade", n=400, rate=3200, slo=overload)
    assert res.ingress["shed"] + res.ingress["degraded"] > 0


if __name__ == "__main__":
    main()
