"""LLM approximation (paper Strategy 2): completion cache + distillation.

Run: PYTHONPATH=src python examples/approximation.py
"""
import numpy as np

from repro.core import approx, neural_market as NM
from repro.core.distill import distill
from repro.core.scorer import SCORER_CFG, train_scorer
from repro.data import synthetic


def main():
    # one "expensive" teacher API
    NM.TIERS = {"GPT-4": NM.TIERS["GPT-4"]}
    NM.TIERS["GPT-4"]["steps"] = 250
    print("== training the expensive teacher ==")
    teacher = NM.train_marketplace("overruling", seed=0, verbose=True)[0]

    # ---- completion cache (Fig 2c) ----------------------------------------
    print("\n== completion cache ==")
    base = synthetic.sample("overruling", 128, seed=5)
    # request stream with heavy repetition (same queries re-asked)
    idx = np.random.default_rng(0).integers(0, 128, size=1024)
    stream = base.tokens[idx]
    # embeddings from a small encoder (scorer backbone, untrained is fine
    # for exact-repeat detection; trained embeddings catch near-duplicates)
    from repro.models.classifier import init_classifier
    import jax
    enc = init_classifier(jax.random.PRNGKey(0), SCORER_CFG, 1)
    emb = approx.embed_queries(enc, stream, SCORER_CFG)
    cache = approx.CompletionCache(capacity=512, threshold=0.995)
    total_cost = 0.0
    for i in range(0, len(stream), 64):      # requests arrive in batches
        _, cost, _ = approx.serve_with_cache(
            cache, emb[i:i + 64], stream[i:i + 64],
            teacher.answer, teacher.query_cost)
        total_cost += cost.sum()
    full_cost = teacher.query_cost(stream).sum()
    print(f"hit rate {cache.hit_rate:.2f}; cost ${total_cost:.4f} vs "
          f"${full_cost:.4f} uncached -> "
          f"{100*(1-total_cost/full_cost):.0f}% saved")

    # ---- distillation (Fig 2d) --------------------------------------------
    print("\n== model fine-tuning (distillation) ==")
    student = distill(teacher, "overruling", n_unlabeled=1024, steps=200)
    test = synthetic.sample("overruling", 512, seed=99)
    t_acc = (teacher.answer(test.tokens) == test.labels).mean()
    s_acc = (student.answer(test.tokens) == test.labels).mean()
    t_cost = teacher.query_cost(test.tokens).mean()
    s_cost = student.query_cost(test.tokens).mean()
    print(f"teacher acc {t_acc:.3f} @ ${t_cost:.6f}/query")
    print(f"student acc {s_acc:.3f} @ ${s_cost:.6f}/query "
          f"({100*(1-s_cost/t_cost):.0f}% cheaper)")


if __name__ == "__main__":
    main()
