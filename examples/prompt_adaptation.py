"""Prompt adaptation (paper Strategy 1): prompt selection + query
concatenation cost accounting.

Run: PYTHONPATH=src python examples/prompt_adaptation.py
"""
import numpy as np

from repro.core.cost import TABLE1
from repro.core.prompt import concat_savings, select_prompt
from repro.core.simulate import DATASETS


def main():
    # ---- prompt selection (Fig 2a) -----------------------------------------
    # in-context examples have diminishing returns; the greedy selector
    # finds the knee. Accuracy model fit to the paper's 8-shot HEADLINES.
    rng = np.random.default_rng(0)
    gains = sorted(rng.uniform(0.01, 0.06, size=8), reverse=True)

    def evaluate(ids):
        return 0.70 + sum(gains[i] for i in ids)

    spec, hist = select_prompt(list(range(8)), evaluate,
                               tokens_per_example=110, base_tokens=140,
                               min_gain=0.02)
    print("greedy prompt selection:")
    for h in hist:
        print(f"  {len(h['examples'])} examples -> acc {h['acc']:.3f}")
    full_tokens = 140 + 8 * 110
    print(f"kept {len(spec.example_ids)}/8 examples: {spec.n_tokens} vs "
          f"{full_tokens} tokens ({100*(1-spec.n_tokens/full_tokens):.0f}% "
          f"prompt cost saved)")

    # ---- query concatenation (Fig 2b) --------------------------------------
    print("\nquery concatenation savings (GPT-4, HEADLINES-sized prompts):")
    ds = DATASETS["HEADLINES"]
    sav = concat_savings(TABLE1["GPT-4"], prompt_tokens=ds["n_in"] - 80,
                         query_tokens=80, gen_tokens=ds["n_out"])
    for g, s in sav.items():
        print(f"  {g:2d} queries/prompt -> {100*s:.0f}% saved per query")


if __name__ == "__main__":
    main()
